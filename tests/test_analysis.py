"""Tests for repro.analysis — the static lint pass (DESIGN.md §12).

Three layers: every rule catches its seeded fixture at the right
file/line (the analyzer's teeth), the real tree is clean for the gated
scopes (the analyzer's value), and the baseline/CLI workflow behaves
(regen, drift, gated-scope refusal, exit codes).  Plus unit tests for
the TSan-lite runtime lock checker.
"""
import json
import pathlib
import threading

import pytest

from repro.analysis import check as check_cli
from repro.analysis import model, rules
from repro.analysis.lockcheck import (CheckedCondition, CheckedLock,
                                      LockDisciplineError, LockRegistry)
from repro.analysis.model import Finding

ROOT = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = ROOT / "tests" / "analysis_fixtures"


def _findings(name):
    return check_cli.check_paths([FIXTURES / name], ROOT)


def _lines(findings, rule_id):
    return sorted(f.line for f in findings if f.rule_id == rule_id)


# ---------------------------------------------------------------------------
# one fixture per rule ID, asserting file + line
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture,rule_id,count", [
    ("jax001_traced_branch.py", "JAX001", 2),
    ("jax002_host_sync.py", "JAX002", 3),
    ("jax003_pow2_ladder.py", "JAX003", 3),
    ("jax004_int32_cumsum.py", "JAX004", 2),
    ("lock001_unguarded_write.py", "LOCK001", 2),
    ("lock002_lock_cycle.py", "LOCK002", 1),
    ("api001_bare_raise.py", "API001", 2),
    ("api002_shim_import.py", "API002", 2),
    ("inc001_stream_splice.py", "INC001", 4),
])
def test_rule_catches_seeded_fixture(fixture, rule_id, count):
    found = _findings(fixture)
    expected = [line for rid, line
                in check_cli._expected_markers(FIXTURES / fixture)
                if rid == rule_id]
    assert len(expected) == count, "fixture markers drifted"
    assert _lines(found, rule_id) == sorted(expected)
    # and nothing else fires on the fixture (negative cases stay clean)
    assert {f.rule_id for f in found} == {rule_id}
    assert all(f.path == f"tests/analysis_fixtures/{fixture}"
               for f in found)


def test_self_check_covers_every_rule():
    assert check_cli.self_check(ROOT, FIXTURES) == 0


def test_repo_rule_flags_tracked_bytecode():
    from repro.analysis.api_rules import check_tracked_artifacts
    bad = ["pkg/__pycache__/m.cpython-310.pyc", "old.pyc",
           "dist/x.egg-info/PKG-INFO"]
    out = check_tracked_artifacts(["src/ok.py", "README.md"] + bad)
    assert sorted(f.path for f in out) == sorted(bad)
    assert all(f.rule_id == "REPO001" for f in out)


# ---------------------------------------------------------------------------
# the real tree: gated scopes are clean, baseline covers the rest
# ---------------------------------------------------------------------------

def test_real_tree_gated_scopes_have_zero_findings():
    findings = check_cli.collect_findings(ROOT)
    gated = [f for f in findings
             if f.path.startswith(model.STRICT_SCOPES)
             or f.rule_id == "REPO001"]
    assert gated == [], [f.render() for f in gated]


def test_real_tree_is_clean_modulo_committed_baseline():
    findings = check_cli.collect_findings(ROOT)
    baseline = model.load_baseline(ROOT / "tests" / "analysis_baseline.json")
    new, stale = model.apply_baseline(findings, baseline)
    assert new == [], [f.render() for f in new]
    assert stale == [], [f.render() for f in stale]


def test_cli_exit_codes(tmp_path):
    # clean repo with the committed baseline
    assert check_cli.main(["--root", str(ROOT)]) == 0
    # each fixture is nonzero through --paths
    for fx in sorted(FIXTURES.glob("*.py")):
        assert check_cli.main(
            ["--root", str(ROOT), "--paths", str(fx)]) == 1, fx.name
    # unknown rule id is a configuration error
    assert check_cli.main(["--root", str(ROOT), "--rules", "NOPE999"]) == 2


# ---------------------------------------------------------------------------
# baseline workflow: regen / drift / gated-scope refusal
# ---------------------------------------------------------------------------

def test_baseline_regen_roundtrip(tmp_path):
    bl = tmp_path / "baseline.json"
    assert check_cli.main(["--root", str(ROOT), "--baseline", str(bl),
                           "--regen"]) == 0
    # freshly regenerated baseline => clean
    assert check_cli.main(["--root", str(ROOT), "--baseline", str(bl)]) == 0
    # drift: drop one entry -> that finding is "new" again -> exit 1
    data = json.loads(bl.read_text())
    assert data["findings"], "expected baselined findings in this repo"
    data["findings"] = data["findings"][1:]
    bl.write_text(json.dumps(data))
    assert check_cli.main(["--root", str(ROOT), "--baseline", str(bl)]) == 1


def test_baseline_stale_entry_forces_regen(tmp_path):
    bl = tmp_path / "baseline.json"
    check_cli.main(["--root", str(ROOT), "--baseline", str(bl), "--regen"])
    data = json.loads(bl.read_text())
    data["findings"].append({
        "rule": "API001", "path": "src/repro/train/checkpoint.py",
        "line": 9999, "message": "a finding that no longer exists"})
    bl.write_text(json.dumps(data))
    # the fixed-but-still-baselined entry must fail the run (deliberate
    # --regen is the only way to shrink the baseline)
    assert check_cli.main(["--root", str(ROOT), "--baseline", str(bl)]) == 1


def test_baseline_refuses_gated_scope_entries(tmp_path):
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps({"version": 1, "findings": [{
        "rule": "API001", "path": "src/repro/core/sweep.py",
        "line": 1, "message": "may not be baselined"}]}))
    with pytest.raises(model.BaselineError):
        model.load_baseline(bl)
    assert check_cli.main(["--root", str(ROOT), "--baseline", str(bl)]) == 2
    # and save_baseline refuses to create one
    with pytest.raises(model.BaselineError):
        model.save_baseline(bl, [Finding(
            "API001", "src/repro/core/sweep.py", 1, "nope")])


def test_baseline_suppression_is_line_number_free():
    f1 = Finding("API001", "src/x.py", 10, "msg")
    f2 = Finding("API001", "src/x.py", 99, "msg")
    new, stale = model.apply_baseline([f2], [f1])
    assert new == [] and stale == []


# ---------------------------------------------------------------------------
# rule registry
# ---------------------------------------------------------------------------

def test_registry_has_all_builtin_rules():
    have = set(rules.all_rules())
    assert have == {"JAX001", "JAX002", "JAX003", "JAX004",
                    "LOCK001", "LOCK002", "API001", "API002", "REPO001",
                    "INC001"}


def test_registry_rejects_duplicates_and_bad_rules():
    from repro.analysis.rules import Rule
    with pytest.raises(ValueError):
        rules.register(Rule(rule_id="API001", name="dup",
                            description="d", check_file=lambda sf: []))
    with pytest.raises(ValueError):        # must have exactly one checker
        Rule(rule_id="X999", name="none", description="d")


# ---------------------------------------------------------------------------
# TSan-lite runtime checker
# ---------------------------------------------------------------------------

def test_checkedlock_out_of_order_acquisition_raises():
    reg = LockRegistry()
    a = CheckedLock("a", reg)
    b = CheckedLock("b", reg)
    with a:
        with b:                      # a -> b follows registration order
            pass
    with b:
        with pytest.raises(LockDisciplineError, match="acquisition order"):
            with a:                  # b -> a violates it
                pass
    assert reg.violations


def test_checkedlock_assert_held_flags_unguarded_write():
    reg = LockRegistry()
    lock = CheckedLock("l", reg)
    with pytest.raises(LockDisciplineError, match="unguarded write"):
        lock.assert_held()
    with lock:
        lock.assert_held()           # held: no error
    snap = reg.snapshot()
    assert snap["acquisitions"]["l"] == 1


def test_checkedlock_nonstrict_records_instead_of_raising():
    reg = LockRegistry(strict=False)
    lock = CheckedLock("l", reg)
    lock.assert_held()
    assert len(reg.violations) == 1


def test_checkedlock_counts_contention():
    reg = LockRegistry()
    lock = CheckedLock("l", reg)
    entered = threading.Event()
    release = threading.Event()

    def holder():
        with lock:
            entered.set()
            release.wait(5.0)

    th = threading.Thread(target=holder)
    th.start()
    entered.wait(5.0)
    got = lock.acquire(blocking=False)    # contended fast-path failure
    assert not got
    release.set()
    th.join()
    with lock:
        pass
    snap = reg.snapshot()
    assert snap["acquisitions"]["l"] == 2
    assert snap["contended"]["l"] >= 0    # nonblocking miss is not counted


def test_checkedcondition_wait_keeps_held_set_truthful():
    reg = LockRegistry()
    lock = CheckedLock("l", reg)
    cond = CheckedCondition(lock)
    hits = []

    def waiter():
        with cond:
            hits.append(reg.held_by_current_thread())
            cond.wait(timeout=5.0)
            hits.append(reg.held_by_current_thread())
        hits.append(reg.held_by_current_thread())

    th = threading.Thread(target=waiter)
    th.start()
    # wake it up (notify needs the lock on the notifier side too)
    import time
    time.sleep(0.1)
    with cond:
        cond.notify_all()
    th.join()
    assert hits == [["l"], ["l"], []]


def test_checkedcondition_wait_without_lock_is_a_violation():
    reg = LockRegistry(strict=False)
    lock = CheckedLock("l", reg)
    cond = CheckedCondition(lock)
    with pytest.raises(RuntimeError):
        cond.wait(timeout=0.01)          # stdlib raises un-acquired error
    assert any("without" in v for v in reg.violations)


def test_duplicate_lock_names_are_uniquified():
    reg = LockRegistry()
    a1 = CheckedLock("session:x", reg)
    a2 = CheckedLock("session:x", reg)
    assert a1.name == "session:x" and a2.name == "session:x#2"
    assert a2.rank > a1.rank
