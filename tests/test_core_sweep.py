"""Parallel SBM correctness: exact agreement with brute force on adversarial
inputs (ties, duplicates, zero-length, containment), across scan backends and
segment counts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st
from repro.core import (
    Extents,
    active_sets_at_segment_starts,
    brute_force_count_numpy,
    make_uniform_workload,
    sbm_count,
    sbm_count_exact,
    sequential_sbm_count_numpy,
    sequential_sbm_pairs_numpy,
)

jax.config.update("jax_platform_name", "cpu")


def _mk(lo_s, hi_s, lo_u, hi_u):
    subs = Extents(jnp.asarray(lo_s, jnp.float32), jnp.asarray(hi_s, jnp.float32))
    upds = Extents(jnp.asarray(lo_u, jnp.float32), jnp.asarray(hi_u, jnp.float32))
    return subs, upds


def test_paper_figure1_example():
    # Fig. 1 of the paper (projected to 1-D x-axis, hand-made coordinates):
    # S1=[0,4], S2=[3,8], S3=[6,14], U1=[1,7], U2=[9,13]
    subs, upds = _mk([0, 3, 6], [4, 8, 14], [1, 9], [7, 13])
    # overlaps: (S1,U1), (S2,U1), (S3,U1), (S3,U2) → 4 (paper reports 4 in 2-D)
    assert int(sbm_count(subs, upds)) == 4
    assert sequential_sbm_count_numpy(subs, upds) == 4


@pytest.mark.parametrize("scan_impl", ["two_level", "blelloch", "xla"])
@pytest.mark.parametrize("num_segments", [1, 2, 8, 32])
def test_matches_brute_force_random(scan_impl, num_segments):
    key = jax.random.PRNGKey(0)
    subs, upds = make_uniform_workload(key, 100, 140, alpha=2.0, length=1000.0)
    want = brute_force_count_numpy(subs, upds)
    got = int(sbm_count(subs, upds, num_segments=num_segments, scan_impl=scan_impl))
    assert got == want


@pytest.mark.parametrize("alpha", [0.01, 1.0, 100.0])
def test_alpha_sweep(alpha):
    key = jax.random.PRNGKey(1)
    subs, upds = make_uniform_workload(key, 300, 300, alpha=alpha)
    assert int(sbm_count(subs, upds)) == brute_force_count_numpy(subs, upds)


def test_touching_endpoints_closed_semantics():
    # S ends exactly where U begins → closed intervals intersect.
    subs, upds = _mk([0.0], [5.0], [5.0], [9.0])
    assert int(sbm_count(subs, upds)) == 1
    # and the mirror
    subs, upds = _mk([5.0], [9.0], [0.0], [5.0])
    assert int(sbm_count(subs, upds)) == 1


def test_zero_length_intervals():
    subs, upds = _mk([2.0, 4.0], [2.0, 4.0], [2.0], [2.0])
    # S1=[2,2] matches U=[2,2]; S2=[4,4] does not.
    assert int(sbm_count(subs, upds)) == 1


def test_identical_intervals_all_pairs():
    n = 17
    subs, upds = _mk([1.0] * n, [2.0] * n, [1.5] * 13, [3.0] * 13)
    assert int(sbm_count(subs, upds)) == n * 13


def test_containment_and_duplicates():
    subs, upds = _mk([0, 0, 1, 1], [10, 10, 2, 2], [1, 0, 5], [2, 100, 5])
    assert int(sbm_count(subs, upds)) == brute_force_count_numpy(
        *_mk([0, 0, 1, 1], [10, 10, 2, 2], [1, 0, 5], [2, 100, 5]))


def test_empty_sets():
    subs, upds = _mk([], [], [1.0], [2.0])
    assert int(sbm_count(subs, upds)) == 0
    subs, upds = _mk([1.0], [2.0], [], [])
    assert int(sbm_count(subs, upds)) == 0


def _check_counts_and_pairs(ls, hs, lu, hu):
    from repro.core import brute_force_pairs_numpy
    subs, upds = _mk(ls, hs, lu, hu)
    want = brute_force_count_numpy(subs, upds)
    assert int(sbm_count(subs, upds, num_segments=4)) == want
    assert sequential_sbm_count_numpy(subs, upds) == want
    assert sequential_sbm_pairs_numpy(subs, upds) == \
        brute_force_pairs_numpy(subs, upds)


def _random_interval_sets(rng, max_size=40, integer=False):
    """Adversarial random sets: integer grids produce heavy ties."""
    n = rng.randint(1, max_size + 1)
    m = rng.randint(1, max_size + 1)

    def mk(count):
        if integer:
            lo = rng.randint(-10, 11, count).astype(float)
            hi = lo + rng.randint(0, 6, count)
        else:
            a = rng.uniform(-1e4, 1e4, count)
            b = rng.uniform(-1e4, 1e4, count)
            lo, hi = np.minimum(a, b), np.maximum(a, b)
        return lo.tolist(), hi.tolist()

    return mk(n) + mk(m)


@pytest.mark.parametrize("seed", range(12))
def test_random_examples_agree(seed):
    """Example-based property sweep (runs with or without hypothesis)."""
    rng = np.random.RandomState(seed)
    for _ in range(4):
        ls, hs, lu, hu = _random_interval_sets(rng, integer=(seed % 2 == 0))
        _check_counts_and_pairs(ls, hs, lu, hu)


if HAVE_HYPOTHESIS:
    # allow_subnormal=False: XLA CPU flushes float32 denormals to zero, numpy
    # does not — comparisons at ~1e-42 would differ between oracle and sweep.
    finite_floats = st.floats(min_value=-1e4, max_value=1e4, allow_nan=False,
                              width=32, allow_subnormal=False)

    @st.composite
    def interval_sets(draw):
        n = draw(st.integers(1, 40))
        m = draw(st.integers(1, 40))

        def mk(count):
            lows, highs = [], []
            for _ in range(count):
                a = draw(finite_floats)
                b = draw(finite_floats)
                lows.append(min(a, b))
                highs.append(max(a, b))
            return lows, highs

        ls, hs = mk(n)
        lu, hu = mk(m)
        return ls, hs, lu, hu

    @given(interval_sets())
    @settings(max_examples=60, deadline=None)
    def test_property_count_and_pairs_equal_brute_force(data):
        _check_counts_and_pairs(*data)


# ---------------------------------------------------------------------------
# wide accumulation: K ≥ 2³¹ must not wrap (regression for the silent
# int32 overflow in jnp.sum(emit) / the enumeration offset table)
# ---------------------------------------------------------------------------

def _all_overlapping(n, m):
    """Duplicated extents: K = n·m with a stream of only 2(n+m) endpoints —
    the cheap construction for counts beyond 2³¹."""
    subs = Extents(jnp.zeros(n, jnp.float32), jnp.ones(n, jnp.float32))
    upds = Extents(jnp.full(m, 0.5, jnp.float32), jnp.full(m, 2.0, jnp.float32))
    return subs, upds


def test_count_beyond_int32_is_exact_and_saturates():
    n = m = 1 << 16                      # K = 2³² > 2³¹
    subs, upds = _all_overlapping(n, m)
    assert sbm_count_exact(subs, upds) == n * m
    got = int(sbm_count(subs, upds))
    if jax.config.read("jax_enable_x64"):
        assert got == n * m              # exact int64
    else:
        assert got == 2**31 - 1          # documented sentinel, never a wrap


def test_count_exact_agrees_below_int32():
    for seed in range(3):
        subs, upds = make_uniform_workload(jax.random.PRNGKey(seed), 120, 90,
                                           alpha=5.0, length=500.0)
        want = brute_force_count_numpy(subs, upds)
        assert sbm_count_exact(subs, upds) == want == int(sbm_count(subs, upds))
    assert sbm_count_exact(*_mk([], [], [1.0], [2.0])) == 0


def test_enumerate_offsets_beyond_int32():
    """With K ≥ 2³¹ the offset table must stay monotonic (saturate, not
    wrap): emitted pairs are still genuine and the count pins at the
    sentinel instead of going negative."""
    from repro.core import sbm_enumerate
    n = m = 1 << 16
    subs, upds = _all_overlapping(n, m)
    pairs, count = sbm_enumerate(subs, upds, max_pairs=16)
    got = int(count)
    if jax.config.read("jax_enable_x64"):
        assert got == n * m
    else:
        assert got == 2**31 - 1
    arr = np.asarray(pairs)
    assert np.all(arr >= 0) and np.all(arr[:, 0] < n) and np.all(arr[:, 1] < m)


def test_saturating_cumsum_contract():
    from repro.core.prefix import cumsum_saturating_i32
    x = jnp.asarray([1, 2, 3, 4], jnp.int32)
    np.testing.assert_array_equal(np.asarray(cumsum_saturating_i32(x)),
                                  [1, 3, 6, 10])          # exact below 2³¹
    big = jnp.full((5,), 2**30, jnp.int32)
    got = np.asarray(cumsum_saturating_i32(big))
    assert got[0] == 2**30 and got[1] == 2**31 - 1        # saturated
    assert np.all(np.diff(got) >= 0), "must stay monotonic past saturation"
    assert got[-1] == 2**31 - 1


def test_algorithm6_active_sets_match_sequential():
    """SubSet[p]/UpdSet[p] (Alg. 6 lines 18-21) equal the sequential sweep's
    state right after segment T_{p-1} — the paper's correctness condition."""
    key = jax.random.PRNGKey(7)
    subs, upds = make_uniform_workload(key, 48, 40, alpha=8.0, length=100.0)
    num_segments = 8
    ep, sub_active, upd_active = active_sets_at_segment_starts(
        subs, upds, num_segments)
    # Sequential replay over the same (sorted, padded) endpoint stream:
    values = np.asarray(ep.values)
    is_up = np.asarray(ep.is_upper)
    is_sub = np.asarray(ep.is_sub)
    owner = np.asarray(ep.owner)
    total = values.shape[0]
    seg = total // num_segments
    cur_s, cur_u = set(), set()
    for p in range(num_segments):
        got_s = set(np.nonzero(np.asarray(sub_active[p]))[0].tolist())
        got_u = set(np.nonzero(np.asarray(upd_active[p]))[0].tolist())
        assert got_s == cur_s, f"segment {p}: SubSet mismatch"
        assert got_u == cur_u, f"segment {p}: UpdSet mismatch"
        for k in range(p * seg, (p + 1) * seg):
            if owner[k] < 0:
                continue
            tgt = cur_s if is_sub[k] else cur_u
            if is_up[k]:
                tgt.discard(int(owner[k]))
            else:
                tgt.add(int(owner[k]))
