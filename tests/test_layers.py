"""Focused layer tests: blockwise attention == dense, MoE dispatch
invariants (hypothesis), Mamba chunked SSD == naive recurrence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, given, settings, st
from repro.configs import ShapeDef, get_config, reduce_config
from repro.models import ModelConfig, LayerSpec
from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models.api import init_params
from repro.parallel.sharding import Sharder

jax.config.update("jax_platform_name", "cpu")
SH = Sharder()


# ---------------------------------------------------------------------------
# blockwise vs dense attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,causal", [(None, True), (48, True),
                                           (None, False)])
def test_blockwise_equals_dense(window, causal):
    b, h, kvh, s, hd = 2, 4, 2, 256, 32
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, h, s, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, kvh, s, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, kvh, s, hd))
    got = attn_lib.blockwise_attention(
        q, k, v, scale=hd ** -0.5, causal=causal, window=window,
        softcap=None, block_q=64, block_k=64)
    want = attn_lib.dense_attention(
        q, k, v, scale=hd ** -0.5, causal=causal, window=window, softcap=None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_with_segments_and_softcap():
    b, h, s, hd = 2, 2, 128, 32
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (b, h, s, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, h, s, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, h, s, hd))
    seg = jnp.cumsum(jax.random.bernoulli(jax.random.fold_in(key, 3),
                                          0.05, (b, s)), axis=1).astype(jnp.int32)
    got = attn_lib.blockwise_attention(
        q, k, v, scale=hd ** -0.5, causal=True, window=None, softcap=20.0,
        block_q=32, block_k=32, q_segments=seg, kv_segments=seg)
    want = attn_lib.dense_attention(
        q, k, v, scale=hd ** -0.5, causal=True, window=None, softcap=20.0,
        q_segments=seg, kv_segments=seg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# MoE sort-based dispatch
# ---------------------------------------------------------------------------

def _check_dispatch_invariants(seed, e, cap_pow):
    cap = 2 ** cap_pow
    rng = np.random.RandomState(seed % 2 ** 31)
    r = rng.randint(1, 64)
    ids = jnp.asarray(rng.randint(0, e, size=(r,)), jnp.int32)
    bins, kept, slot = moe_lib.sort_based_dispatch(ids, cap, e)
    bins = np.asarray(bins)
    kept = np.asarray(kept)
    slot = np.asarray(slot)
    # every bin entry points to a record routed to that expert
    for ei in range(e):
        entries = bins[ei][bins[ei] >= 0]
        assert all(int(ids[j]) == ei for j in entries)
        assert len(set(entries.tolist())) == len(entries)   # no duplicates
    # kept records appear exactly once; dropped never appear
    flat = bins[bins >= 0].tolist()
    assert sorted(flat) == sorted(np.nonzero(kept)[0].tolist())
    # capacity respected; earliest records win (stable sort)
    counts = np.bincount(np.asarray(ids), minlength=e)
    for ei in range(e):
        assert (bins[ei] >= 0).sum() == min(counts[ei], cap)


@pytest.mark.parametrize("seed,e,cap_pow",
                         [(0, 2, 1), (1, 16, 4), (2, 7, 2), (3, 3, 3),
                          (4, 11, 1)])
def test_dispatch_invariants_examples(seed, e, cap_pow):
    _check_dispatch_invariants(seed, e, cap_pow)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2 ** 31 - 1), st.integers(2, 16), st.integers(1, 4))
    @settings(max_examples=30, deadline=None)
    def test_dispatch_invariants(seed, e, cap_pow):
        _check_dispatch_invariants(seed, e, cap_pow)


def test_moe_layer_exactness_vs_dense_compute():
    """With capacity ≥ tokens·k, MoE output must equal the explicit
    gather-free computation (every token through its top-k experts)."""
    cfg = dataclasses.replace(
        reduce_config(get_config("granite-moe-3b-a800m")),
        moe_capacity_factor=64.0)      # no drops
    params = init_params(jax.random.PRNGKey(0),
                         moe_lib.moe_defs(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = moe_lib.moe_layer(params, x, cfg, SH)
    assert float(aux["moe_drop_fraction"]) == 0.0

    # dense reference
    logits = jnp.einsum("bsd,de->bse", x, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, choice = jax.lax.top_k(probs, cfg.num_experts_per_token)
    gate = gate / gate.sum(-1, keepdims=True)
    g = jnp.einsum("bsd,edf->bsef", x, params["w_gate"])
    u = jnp.einsum("bsd,edf->bsef", x, params["w_up"])
    y_all = jnp.einsum("bsef,efd->bsed", jax.nn.silu(g) * u, params["w_down"])
    want = jnp.zeros_like(x)
    for kk in range(cfg.num_experts_per_token):
        sel = jnp.take_along_axis(y_all, choice[..., kk][..., None, None],
                                  axis=2)[..., 0, :]
        want = want + gate[..., kk][..., None] * sel
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_are_reported():
    cfg = dataclasses.replace(
        reduce_config(get_config("granite-moe-3b-a800m")),
        moe_capacity_factor=0.25)
    params = init_params(jax.random.PRNGKey(0),
                         moe_lib.moe_defs(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    _, aux = moe_lib.moe_layer(params, x, cfg, SH)
    assert float(aux["moe_drop_fraction"]) > 0.0


# ---------------------------------------------------------------------------
# Mamba-2 SSD: chunked == naive recurrence; decode == train
# ---------------------------------------------------------------------------

def _naive_ssd(xh, dt, a_log, bmat, cmat, h0):
    b, s, hm, p = xh.shape
    A = -np.exp(np.asarray(a_log, np.float64))
    h = np.asarray(h0, np.float64).copy()
    ys = np.zeros((b, s, hm, p))
    xh = np.asarray(xh, np.float64)
    dt = np.asarray(dt, np.float64)
    bm = np.asarray(bmat, np.float64)
    cm = np.asarray(cmat, np.float64)
    for t in range(s):
        a = np.exp(dt[:, t] * A)                         # (B,Hm)
        dbx = np.einsum("bh,bn,bhp->bhnp", dt[:, t], bm[:, t], xh[:, t])
        h = a[:, :, None, None] * h + dbx
        ys[:, t] = np.einsum("bn,bhnp->bhp", cm[:, t], h)
    return ys, h


@pytest.mark.parametrize("s", [64, 128, 256, 384])
def test_ssd_chunked_equals_naive(s):
    b, hm, p, n = 2, 3, 8, 4
    key = jax.random.PRNGKey(0)
    xh = jax.random.normal(key, (b, s, hm, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, s, hm)))
    a_log = jax.random.normal(jax.random.fold_in(key, 2), (hm,)) * 0.3
    bm = jax.random.normal(jax.random.fold_in(key, 3), (b, s, n))
    cm = jax.random.normal(jax.random.fold_in(key, 4), (b, s, n))
    h0 = jax.random.normal(jax.random.fold_in(key, 5), (b, hm, n, p))
    y, h = mamba_lib._ssd_chunked(xh, dt, a_log, bm, cm, h0)
    y_ref, h_ref = _naive_ssd(xh, dt, a_log, bm, cm, h0)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=2e-4, atol=2e-4)


def test_mamba_layer_decode_equals_parallel():
    cfg = reduce_config(get_config("mamba2-2.7b"))
    params = init_params(jax.random.PRNGKey(0), mamba_lib.mamba_defs(cfg),
                         jnp.float32)
    b, s = 2, 48
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.3
    # parallel (chunked) pass over the whole sequence
    y_par, _ = mamba_lib.mamba_layer(params, x, cfg, SH, state=None)
    # stateful: prefill s-8, then 8 decode steps
    st = mamba_lib.init_mamba_state(cfg, b)
    y_pre, st = mamba_lib.mamba_layer(params, x[:, :s - 8], cfg, SH, state=st)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_par[:, :s - 8]),
                               rtol=2e-4, atol=2e-4)
    for t in range(s - 8, s):
        y_t, st = mamba_lib.mamba_layer(params, x[:, t:t + 1], cfg, SH, state=st)
        np.testing.assert_allclose(np.asarray(y_t[:, 0]),
                                   np.asarray(y_par[:, t]),
                                   rtol=2e-4, atol=2e-4)
